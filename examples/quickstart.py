"""Quickstart: train LDA with POBP on a synthetic corpus, compare the
paper's power-selected sync against the dense MPA baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import LDAConfig, perplexity, run_stream
from repro.data import (docs_to_padded, lda_corpus, sharded_minibatch_stream,
                        train_test_split_counts)


def main():
    # a small corpus with known LDA structure
    docs, stats, _ = lda_corpus(seed=0, num_docs=300, vocab_size=400,
                                num_topics=16, doc_len_mean=80)
    print(f"corpus: {stats}")
    train, test = train_test_split_counts(docs, seed=0)
    tr_b, te_b = docs_to_padded(train), docs_to_padded(test)
    key = jax.random.PRNGKey(5)

    cfg = LDAConfig(vocab_size=400, num_topics=16, lambda_w=0.1,
                    lambda_k_abs=8, inner_iters=40, residual_tol=0.03)

    for mode in ("power", "dense"):
        phi, hist, meter = run_stream(
            sharded_minibatch_stream(train, 100, num_shards=4), cfg,
            num_shards=4, sync_mode=mode, seed=1)
        ppl = perplexity.evaluate(key, phi, tr_b, te_b, cfg)
        loop_phase = "power" if mode == "power" else "dense_loop"
        print(f"[{mode:5s}] perplexity={ppl:7.2f}  "
              f"loop sync bytes/iter={meter.phase_bytes(loop_phase):,}  "
              f"mini-batches={len(hist)}")

    rand = perplexity.evaluate(key, jnp.zeros((400, 16)), tr_b, te_b, cfg)
    print(f"[random] perplexity={rand:7.2f}  (untrained baseline)")
    print("power sync sends ~= lambda_w * lambda_k of the dense payload "
          "per iteration (paper Eq. 6 vs Eq. 5) at comparable perplexity.")

    # ---- serve the model you just trained ------------------------------
    # the paper's deployment story: phi is frozen, incoming documents get
    # topic mixtures by fold-in.  FoldInEngine batches requests per length
    # bucket and runs the same token-major inference body eval used above.
    from repro.serve import FoldInEngine

    engine = FoldInEngine(phi, cfg, len_buckets=(16, 32, 64, 128),
                          batch_docs=32, residual_tol=0.01)
    for doc in test:
        engine.submit(doc)
    results = engine.drain()
    s = engine.stats()
    top = results[0].theta.argsort()[-3:][::-1]
    print(f"[serve] {s['served']} requests: {s['docs_per_s']:,.0f} docs/s  "
          f"p50={s['latency_p50_s'] * 1e3:.1f}ms  "
          f"p99={s['latency_p99_s'] * 1e3:.1f}ms  "
          f"mean fold iters={s['mean_fold_iters']:.1f}")
    print(f"[serve] request 0 top topics: {top.tolist()}")

    # ---- continuous-batching slab admission (DESIGN.md §16) ------------
    # the bucket ladder above barriers per length rung; SlabEngine keeps
    # one fixed [slots, slot_len] in-flight batch on device, retires each
    # slot when its residual tail clears tol, and refills mid-flight —
    # one compile, no rung barriers.  Repeat documents hit a per-tenant
    # theta cache keyed on content digest + phi_version, so a hot-swap
    # invalidates for free.
    from repro.serve import SlabEngine

    slab = SlabEngine(phi, cfg, slots=16, slot_len=64,
                      theta_cache=512, cache_mode="serve")
    for doc in test:
        slab.submit(doc, tenant="demo")
    slab_results = slab.drain()   # retirement populates the cache
    for doc in test[:8]:          # repeats — served from cache
        slab.submit(doc, tenant="demo")
    slab_results += slab.drain()
    ss = slab.stats()
    print(f"[slab] {ss['served']} served: {ss['docs_per_s']:,.0f} docs/s  "
          f"compiles={ss['compiles']}  occupancy={ss['slot_occupancy']:.2f}  "
          f"cache_served={ss['cache_served']}")
    # the CLI drives the same engine open-loop against an SLO, swaps phi
    # mid-stream, and writes a machine-readable report:
    #
    #   python -m repro.launch.serve --ckpt runs/demo --admission slab \
    #       --qps 1500 --slo-ms 40 --swap-at 0.5 --report-json serve.json

    # ---- adaptive sweep dispatch (DESIGN.md §2 cost model) -------------
    # The selective iteration has two algebraically identical
    # formulations; `sweep_policy="auto"` (the default) picks the cheaper
    # one per (T, K, Pk, P) from rates measured on THIS machine at trace
    # time.  Force one to compare — trajectories and sync bytes are
    # identical either way, only wall-clock moves:
    import dataclasses

    from repro.core.sweep_dispatch import resolve_sweep_policy

    wide = dataclasses.replace(cfg, lambda_k_abs=50)   # paper's lambda_K*K
    for c in (cfg, wide):
        picked = resolve_sweep_policy(c, 100 * 80, c.num_topics,
                                      c.num_power_topics, c.num_power_words)
        print(f"[sweep] Pk={c.num_power_topics:3d}: auto policy -> {picked}"
              "  (force with LDAConfig(sweep_policy=...) or "
              "lda_train --sweep-policy)")

    # ---- ultra-high K (DESIGN.md §13) ----------------------------------
    # On the pallas impl, when the full-K carry megakernel's VMEM
    # footprint stops admitting a useful token tile, `auto` switches to
    # the K-blocked two-pass kernel; phi_acc can also be STORED at bf16
    # (the accumulate stays f32, the fold-back is stochastically rounded)
    # to halve phi HBM and phi-delta sync bytes:
    #
    #   python -m repro.launch.lda_train --impl pallas \
    #       --sweep-policy kblocked --phi-acc-dtype bfloat16
    #
    # `--sweep-policy auto` only engages kblocked past the VMEM budget
    # (REPRO_VMEM_BUDGET_BYTES / LDAConfig.vmem_budget_bytes):
    huge = dataclasses.replace(cfg, num_topics=4096, impl="pallas",
                               vmem_budget_bytes=4_000_000)
    picked = resolve_sweep_policy(huge, 100 * 80, huge.num_topics,
                                  huge.num_power_topics,
                                  huge.num_power_words, n_docs=100)
    print(f"[sweep] K={huge.num_topics} under a 4 MB VMEM budget -> "
          f"{picked}")

    # ---- vocabulary growth (DESIGN.md §12) -----------------------------
    # Real streams grow their vocabulary after step 0.  A VocabMap assigns
    # external token keys to phi rows append-only (deterministic
    # first-seen order); training grows phi along a geometric capacity
    # ladder (see `python -m repro.launch.lda_train --dynamic-vocab`), and
    # serving never crashes on an unseen word — it folds OOV tokens in
    # through a guard row carrying the beta-prior mass.
    import numpy as np

    from repro.data import VocabMap, next_capacity

    vocab = VocabMap()
    rows = vocab.rows(["jax", "pallas", "topic", "jax"])     # admit, dense
    print(f"[vocab] {len(vocab)} live words at rows {rows.tolist()}, "
          f"first capacity rung W_cap={next_capacity(len(vocab))}")
    oov_doc = (np.asarray([0, 1, 399, 1_000_000]),           # last id: OOV
               np.ones(4, np.float32))
    engine.submit(oov_doc)
    (res,) = engine.drain()
    print(f"[vocab] OOV request served finite theta "
          f"(sum={res.theta.sum():.3f}, oov tokens={res.oov_tokens:.0f}, "
          f"engine oov rate={engine.stats()['oov_rate']:.4f})")

    # ---- pull-based parameter server (DESIGN.md §15) -------------------
    # The allreduce backends above ship every power-selected row every
    # iteration.  `--backend ps` row-shards phi across servers and moves
    # only the rows each mini-batch TOUCHED: push sparse deltas, pull
    # next batch's slice one segment ahead, tolerate `--staleness S`
    # versions of lag (S=0 is bit-exact vs allreduce — BENCH_comm pins
    # the drift at <= 1e-6 and the wire at <= 0.5x):
    #
    #   python -m repro.launch.lda_train --backend ps --ps-servers 4 \
    #       --staleness 1 --ps-latency 0.002
    #
    # the same touched-row byte model, standalone (Eq. 6 refined):
    from repro.core.sync import power_sync_bytes, touched_power_sync_bytes

    P, Pk = cfg.num_power_words, cfg.num_power_topics
    for touched in (40, 400):
        print(f"[ps] touched={touched:3d}: "
              f"{touched_power_sync_bytes(P, Pk, touched):,} bytes/iter vs "
              f"allreduce {power_sync_bytes(P, Pk, 400):,}")

    # ---- chaos-hardened runtime (DESIGN.md §17) ------------------------
    # The PS backend survives a hostile network: a seed-replayable
    # FaultPlan drops/duplicates/delays ops and crashes one server
    # mid-stream; retries + sequence-number dedup + version-ordered
    # retained-delta replay keep S=0 training BIT-EXACT with the clean
    # run (BENCH_fault gates it):
    #
    #   python -m repro.launch.lda_train --backend ps --staleness 0 \
    #       --chaos-seed 7 --chaos-drop 0.25 --chaos-dup 0.25 \
    #       --chaos-crash 1@6
    #
    # every fault is a pure function of (seed, op kind, op index):
    from repro.dist.faults import FaultPlan

    plan = FaultPlan(seed=7, drop_push=0.25, dup_push=0.25)
    fates = [plan.decide("push", i) for i in range(200)]
    print(f"[chaos] seed 7, 200 push ops: "
          f"{sum(f.drop for f in fates)} dropped, "
          f"{sum(f.duplicate for f in fates)} duplicated — same every run")

    # ---- stream lifecycle (DESIGN.md §14) ------------------------------
    # A drifting stream must also FORGET: Robbins-Monro decay fades stale
    # phi mass, checkpoint-fenced compaction reclaims rows that went both
    # idle and prior-level, and faded topics are reseeded from emerging
    # words.  The driver wires it all up:
    #
    #   python -m repro.launch.lda_train --dynamic-vocab \
    #       --drift-mode slide --decay 1,0.3 --compact-every 5 \
    #       --recycle-tol 0.01
    #
    # The pieces compose standalone too — compact a vocab + phi pair:
    from repro.core import lifecycle
    from repro.core.pobp import init_train_state

    v = VocabMap(["old", "stale", "fresh"], touched=(0, 0, 9))
    state = init_train_state(dataclasses.replace(cfg, vocab_size=8), seed=0)
    dead = lifecycle.dead_rows(row_mass=np.asarray([0.1, 0.2, 50.0]),
                               last_touched=v.touched_upto(3),
                               step=10, min_idle=5, mass_floor=1.0)
    remap = v.compact(~dead)
    state = lifecycle.apply_row_remap(state, remap)
    print(f"[lifecycle] reclaimed rows {np.nonzero(dead)[0].tolist()}; "
          f"survivors {v.to_state()} at rows "
          f"{[int(r) for r in remap if r >= 0]} — serving hot-swaps the "
          f"pair via FoldInEngine.swap_phi (results carry phi_version)")


if __name__ == "__main__":
    main()
