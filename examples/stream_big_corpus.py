"""End-to-end driver (the paper's kind of workload): stream a corpus
through POBP for hundreds of mini-batch iterations with CONSTANT memory,
checkpointing the full training state for crash recovery.

The corpus is generated on the fly (never fully materialized) — the
life-long/never-ending regime of §3.2 where M -> infinity — and runs on
the production streaming driver (`repro.launch.lda_train`): shape-bucketed
batching, async dispatch, and a real restore path.  Simulate a crash and
watch the rerun RESUME from the latest checkpoint instead of silently
restarting from m=1:

    PYTHONPATH=src python examples/stream_big_corpus.py --minibatches 30 \
        --crash-at 17
    PYTHONPATH=src python examples/stream_big_corpus.py --minibatches 30
    # -> [restore] resumed from checkpoint step 10 -> next minibatch 11

Add `--backend ps --staleness 1` to run the same stream through the
pull-based parameter server (DESIGN.md §15): phi rows live sharded
across servers, each mini-batch pushes only its touched-row deltas and
pulls the next batch's slice one segment ahead — wire bytes drop to the
touched fraction of the allreduce payload, and crash-resume still works
(checkpoints are server-synced at every fence).
"""

import argparse
import os
import resource
import shutil
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minibatches", type=int, default=30)
    ap.add_argument("--docs-per-batch", type=int, default=64)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--backend", default="sim", choices=["sim", "ps"],
                    help="sim = vmap-allreduce; ps = pull-based parameter "
                         "server (touched-row push/pull, DESIGN.md §15)")
    ap.add_argument("--staleness", type=int, default=0,
                    help="ps only: tolerated pull lag in versions; 0 is "
                         "bit-exact with the allreduce backend")
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a hard failure after minibatch N; rerun "
                         "the same command to resume")
    ap.add_argument("--ckpt-dir",
                    default=os.path.join(tempfile.gettempdir(),
                                         "pobp_lda_train_ck"))
    ap.add_argument("--fresh", action="store_true",
                    help="discard any previous checkpoints first")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    from repro.launch.lda_train import default_args, train_loop

    rss = []

    def track_rss(step_no, state, diag):
        # host-side only: reading diag values here would force a sync
        rss.append(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e3)

    run = default_args(
        minibatches=args.minibatches, docs_per_batch=args.docs_per_batch,
        shards=args.shards, vocab=500, topics=16, lambda_k=8,
        inner_iters=20, tol=0.05, doc_len_means="30,60,90",
        len_buckets="32,64,96", log_every=10, eval_every=0,
        ckpt_dir=args.ckpt_dir, ckpt_every=10, crash_at=args.crash_at,
        backend=args.backend, staleness=args.staleness, seed=0)
    res = train_loop(run, on_batch=track_rss)

    n = len(res["mean_r"])
    print(f"\nprocessed {n} mini-batches (resumed at m="
          f"{res['first_m'] + 1}); held-out ppl={res['ppl']:.1f}")
    if len(rss) > 4:
        warm = rss[3:]
        drift = (max(warm) - min(warm)) / max(min(warm), 1)
        print(f"RSS drift after warmup: {drift * 100:.1f}% "
              f"(constant-memory streaming, paper Table 5)")
    print(f"step compiles: {res['compiles']} for buckets "
          f"{res['len_buckets']} (shape-bucketed batching)")
    print(f"per-minibatch sync bytes: {res['per_minibatch_bytes']:,} "
          f"(phases: {res['bytes_by_phase']})")
    if args.backend == "ps":
        print(f"[ps] staleness={res['staleness']}  measured wire/minibatch="
              f"{res['ps_wire_per_minibatch']:,.0f}B  mean touched rows="
              f"{res['mean_touched_rows']:.0f}/500  sync waits: pull="
              f"{res['ps_pull_wait_s']:.3f}s push={res['ps_push_wait_s']:.3f}s")


if __name__ == "__main__":
    main()
