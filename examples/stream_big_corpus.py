"""End-to-end driver (the paper's kind of workload): stream a corpus
through POBP for a few hundred mini-batch iterations with CONSTANT memory,
checkpointing the sufficient statistics for crash recovery.

The corpus is generated on the fly (never fully materialized) — the
life-long/never-ending regime of §3.2 where M -> infinity.

    PYTHONPATH=src python examples/stream_big_corpus.py [--minibatches 30]
"""

import argparse
import os
import resource
import tempfile

import jax
import numpy as np

from repro.core import LDAConfig, perplexity, run_stream
from repro.data import docs_to_padded, lda_corpus, train_test_split_counts
from repro.data.batching import docs_to_padded as pad
from repro.dist import checkpoint as ckpt
from repro.core.types import MiniBatch


def endless_stream(cfg, num_minibatches, docs_per_batch, num_shards,
                   true_phi):
    """Generate mini-batches lazily — memory stays flat regardless of M.
    All batches share the SAME ground-truth topics (life-long regime)."""
    import jax.numpy as jnp
    from repro.data.synthetic import lda_corpus_from_phi
    for m in range(num_minibatches):
        docs, _ = lda_corpus_from_phi(1000 + m, docs_per_batch, true_phi,
                                      doc_len_mean=60)
        b = pad(docs, max_len=48)
        D, L = b.word_ids.shape
        Dp = (D // num_shards) * num_shards
        yield MiniBatch(
            word_ids=jnp.reshape(b.word_ids[:Dp],
                                 (num_shards, Dp // num_shards, L)),
            counts=jnp.reshape(b.counts[:Dp],
                               (num_shards, Dp // num_shards, L)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minibatches", type=int, default=30)
    ap.add_argument("--docs-per-batch", type=int, default=64)
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()

    cfg = LDAConfig(vocab_size=500, num_topics=16, lambda_w=0.1,
                    lambda_k_abs=8, inner_iters=20, residual_tol=0.05)
    ckdir = os.path.join(tempfile.gettempdir(), "pobp_stream_ck")
    # one fixed ground-truth topic set shared by the whole stream
    import numpy as np
    true_phi = np.random.default_rng(42).dirichlet(
        np.full(cfg.vocab_size, 0.06), size=cfg.num_topics).astype(np.float32)

    rss = []

    def cb(m, phi_acc, rec, theta):
        rss.append(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e3)
        if m % 10 == 0:
            ckpt.save(ckdir, m, {"phi": {"acc": phi_acc}},
                      extra={"m": m})  # restartable: learning rate is 1/(m-1)
            print(f"minibatch {m:4d}  mean_r={rec['mean_r']:.4f} "
                  f"iters={rec['iters']:3d}  rss={rss[-1]:.0f}MB "
                  f"[checkpointed]", flush=True)

    stream = endless_stream(cfg, args.minibatches, args.docs_per_batch,
                            args.shards, true_phi)
    phi, hist, meter = run_stream(stream, cfg, num_shards=args.shards,
                                  sync_mode="power", seed=0, callback=cb)

    # held-out evaluation
    from repro.data.synthetic import lda_corpus_from_phi
    docs, _ = lda_corpus_from_phi(9999, 100, true_phi, doc_len_mean=60)
    train, test = train_test_split_counts(docs, 0)
    ppl = perplexity.evaluate(jax.random.PRNGKey(3), phi,
                              docs_to_padded(train), docs_to_padded(test),
                              cfg)
    drift = (max(rss[3:]) - min(rss[3:])) / max(min(rss[3:]), 1)
    print(f"\nprocessed {len(hist)} mini-batches; held-out ppl={ppl:.1f}")
    print(f"RSS drift after warmup: {drift * 100:.1f}% "
          f"(constant-memory streaming, paper Table 5)")
    print(f"total sync bytes by phase: {meter.bytes_by_phase}")


if __name__ == "__main__":
    main()
